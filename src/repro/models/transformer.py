"""Decoder-only transformer LM: RoPE, GQA, {SwiGLU|GeGLU|ReLU²}, MoE option.

Pure-function JAX implementation with scan-over-layers (keeps the lowered HLO
one layer deep — essential for the 512-device dry-run compiles) and optional
per-layer remat. Serving provides prefill (build KV cache) and decode (one
token against a full cache).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import nn
from repro.configs.base import TransformerConfig
from repro.models.moe import init_moe_params, moe_ffn

Params = Dict[str, Any]


def compute_dtype(cfg: TransformerConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def _constrain_batch(x: jax.Array, cfg: TransformerConfig) -> jax.Array:
    """Pin the batch dim to the DP axes (keeps GSPMD from drifting to
    feature-dim sharding inside scan bodies — observed 200+GB temp blowup).

    With ``cfg.seq_parallel_residual`` the sequence dim additionally shards
    over "model" (Megatron-SP): every residual-stream tensor — including the
    remat-saved per-layer inputs, which otherwise replicate a
    [L, B, S, d] stack across the TP axis — shrinks by the TP width.
    """
    if not cfg.batch_axes:
        return x
    from jax.sharding import PartitionSpec as P
    ba = tuple(cfg.batch_axes)
    # SP pairs with the seq-sharded attention strategy (uneven heads); with
    # even head sharding an S-sharded residual makes GSPMD replicate the
    # attention dots (measured 8× FLOPs on gemma prefill — §Perf it. 5)
    heads_uneven = (cfg.tp_width > 0
                    and (cfg.n_heads % cfg.tp_width != 0
                         or cfg.n_kv_heads % cfg.tp_width != 0))
    if (cfg.seq_parallel_residual and heads_uneven
            and x.ndim >= 3 and x.shape[1] > 1):
        spec = P(ba, "model", *([None] * (x.ndim - 2)))
    else:
        spec = P(ba, *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, spec)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_layer_params(cfg: TransformerConfig, key) -> Params:
    ks = jax.random.split(key, 8)
    d = cfg.d_model
    p: Params = {
        "attn_norm": nn.rms_norm_params(d),
        "ffn_norm": nn.rms_norm_params(d),
        "wq": nn.dense_init(ks[0], d, cfg.q_dim),
        "wk": nn.dense_init(ks[1], d, cfg.kv_dim),
        "wv": nn.dense_init(ks[2], d, cfg.kv_dim),
        "wo": nn.dense_init(ks[3], cfg.q_dim, d),
    }
    if cfg.qk_norm:
        p["q_norm"] = nn.rms_norm_params(cfg.head_dim)
        p["k_norm"] = nn.rms_norm_params(cfg.head_dim)
    if cfg.moe is None:
        if cfg.activation in ("swiglu", "geglu"):
            p["w_gate"] = nn.dense_init(ks[4], d, cfg.d_ff)
            p["w_up"] = nn.dense_init(ks[5], d, cfg.d_ff)
        else:
            p["w_up"] = nn.dense_init(ks[5], d, cfg.d_ff)
        p["w_down"] = nn.dense_init(ks[6], cfg.d_ff, d)
    else:
        p["moe"] = init_moe_params(cfg, ks[7])
    return p


def init_params(cfg: TransformerConfig, key) -> Params:
    k_embed, k_layers, k_head = jax.random.split(key, 3)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    if cfg.scan_layers:
        layers = jax.vmap(lambda k: init_layer_params(cfg, k))(layer_keys)
    else:
        layers = [init_layer_params(cfg, k) for k in layer_keys]
    params: Params = {
        "embed": nn.embed_init(k_embed, cfg.vocab_size, cfg.d_model),
        "layers": layers,
        "final_norm": nn.rms_norm_params(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = nn.dense_init(k_head, cfg.d_model, cfg.vocab_size)
    return params


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [B, S, H, hd]; positions: [B, S] (absolute)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-jnp.arange(0, half, dtype=jnp.float32)
                    * (jnp.log(theta) / half))
    angles = positions[..., None].astype(jnp.float32) * freqs  # [B, S, half]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin],
                          axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def gqa_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                  mask: jax.Array) -> jax.Array:
    """q: [B,S,H,hd], k/v: [B,T,KV,hd], mask: [B,1,S,T] or broadcastable.

    Grouped-query: H = KV * G; scores computed per (kv-head, group).
    Materialises [S, T] scores — use only for short S (decode: S=1).
    """
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    q = q.reshape(B, S, KV, G, hd)
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    scores = jnp.einsum("bskgh,btkh->bkgst", q, k,
                        preferred_element_type=jnp.float32) * scale
    scores = jnp.where(mask[:, None, None, :, :] if mask.ndim == 3
                       else mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkh->bskgh", probs, v)
    return out.reshape(B, S, H * hd)


def chunked_causal_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                             cfg: Optional[TransformerConfig] = None,
                             q_block: int = 512,
                             kv_block: int = 1024) -> jax.Array:
    """Flash-style causal GQA: online softmax over KV blocks, scanned over Q
    blocks — never materialises the [S, S] score matrix (needed for the
    4k-train and 32k-prefill shapes; peak per-block [B,KV,G,qb,kb] fp32).

    §Perf knobs (EXPERIMENTS.md): ``cfg.attn_seq_shard`` shards the q-block
    dim over "model" and replicates k/v for the inner product — GQA head
    counts (8/16/24) don't divide a 16-wide TP axis, so head-sharding pads
    unevenly AND all-reduces the score contraction; sequence sharding is
    even for any S and contraction-local. ``cfg.attn_probs_bf16`` keeps the
    saved probability blocks in bf16 (stats stay f32).
    """
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    q_block = min(q_block, S)
    kv_block = min(kv_block, S)
    nq, nk = S // q_block, S // kv_block
    assert S % q_block == 0 and S % kv_block == 0
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    qr = q.reshape(B, nq, q_block, KV, G, hd)
    kr = k.reshape(B, nk, kv_block, KV, hd)
    vr = v.reshape(B, nk, kv_block, KV, hd)
    heads_uneven = (cfg is not None and cfg.tp_width > 0
                    and (cfg.n_heads % cfg.tp_width != 0
                         or cfg.n_kv_heads % cfg.tp_width != 0))
    seq_shard = (cfg is not None and cfg.attn_seq_shard and cfg.batch_axes
                 and heads_uneven)
    probs_bf16 = cfg is not None and cfg.attn_probs_bf16
    if seq_shard:
        from jax.sharding import PartitionSpec as P
        ba = tuple(cfg.batch_axes)
        qr = jax.lax.with_sharding_constraint(
            qr, P(ba, None, "model", None, None, None))
        # k/v replicate across "model" for the block inner product. (Sharding
        # their kv-seq dim was tried and REFUTED — GSPMD all-gathers the
        # contraction instead of doing distributed partial softmax; the
        # shard_map ring-attention that would exploit it is future work.
        # EXPERIMENTS.md §Perf iteration 4.)
        kr = jax.lax.with_sharding_constraint(
            kr, P(ba, None, None, None, None))
        vr = jax.lax.with_sharding_constraint(
            vr, P(ba, None, None, None, None))
    q_pos = jnp.arange(q_block)
    k_pos = jnp.arange(kv_block)

    @partial(jax.checkpoint, static_argnums=())
    def q_step(_, qi):
        # remat: the backward recomputes this q-block's inner sweep instead
        # of saving [nq, nk, B, KV, G, qb, kb] score stacks (DESIGN.md §7)
        qb = qr[:, qi]                                     # [B,qb,KV,G,hd]

        def kv_step(carry, ki):
            m, l, acc = carry
            kb = kr[:, ki]                                 # [B,kb,KV,hd]
            vb = vr[:, ki]
            s = jnp.einsum("bqkgh,btkh->bkgqt", qb, kb,
                           preferred_element_type=jnp.float32) * scale
            valid = (qi * q_block + q_pos)[:, None] >= (ki * kv_block + k_pos)
            s = jnp.where(valid[None, None, None], s, -1e30)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            if probs_bf16:
                # bf16 exp: AD saves the bf16 block (exp bwd keeps its
                # output); stats (m, l) accumulate in f32
                p = jnp.exp((s - m_new[..., None]).astype(jnp.bfloat16))
                l_inc = jnp.sum(p.astype(jnp.float32), axis=-1)
            else:
                p = jnp.exp(s - m_new[..., None])
                l_inc = jnp.sum(p, axis=-1)
            corr = jnp.exp(m - m_new)
            l_new = l * corr + l_inc
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgqt,btkh->bkgqh", p.astype(vb.dtype), vb,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, KV, G, q_block), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, KV, G, q_block), jnp.float32)
        a0 = jnp.zeros((B, KV, G, q_block, hd), jnp.float32)
        # causal: only kv blocks overlapping [0, (qi+1)*q_block) matter, but
        # scan bounds are static — masked full sweep (triangular-schedule
        # skip is a logged hillclimb item in EXPERIMENTS.md §Perf)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), jnp.arange(nk))
        out = acc / jnp.maximum(l, 1e-30)[..., None]       # [B,KV,G,qb,hd]
        return None, out.astype(q.dtype)

    _, outs = jax.lax.scan(q_step, None, jnp.arange(nq))   # [nq,B,KV,G,qb,hd]
    out = jnp.moveaxis(outs, 0, 1)                         # [B,nq,KV,G,qb,hd]
    out = jnp.moveaxis(out, -2, 2)                         # [B,nq,qb,KV,G,hd]
    return out.reshape(B, S, H * hd)


# sequences at or below this use the plain (materialised) attention path
_CHUNKED_ATTN_THRESHOLD = 2048


def _attn_block(p: Params, h: jax.Array, positions: jax.Array,
                mask: jax.Array, cfg: TransformerConfig,
                kv: Optional[Tuple[jax.Array, jax.Array]] = None):
    """Self-attention sublayer; ``kv`` overrides keys/values (decode)."""
    B, S, _ = h.shape
    x = nn.rms_norm({"scale": p["attn_norm"]["scale"]}, h, cfg.norm_eps)
    q = (x @ p["wq"].astype(x.dtype)).reshape(B, S, cfg.n_heads, cfg.head_dim)
    k = (x @ p["wk"].astype(x.dtype)).reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    v = (x @ p["wv"].astype(x.dtype)).reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = nn.rms_norm(p["q_norm"], q, cfg.norm_eps)
        k = nn.rms_norm(p["k_norm"], k, cfg.norm_eps)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    new_kv = (k, v)
    if kv is not None:
        # decode: write this step's k/v into the cache at position, use cache
        cache_k, cache_v, cache_len = kv
        cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k, cache_len, 1)
        cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v, cache_len, 1)
        k, v = cache_k, cache_v
        new_kv = (cache_k, cache_v)
        attn = gqa_attention(q, k, v, mask)
    elif S > _CHUNKED_ATTN_THRESHOLD:
        attn = chunked_causal_attention(q, k, v, cfg)
    else:
        attn = gqa_attention(q, k, v, mask)
    return h + attn @ p["wo"].astype(h.dtype), new_kv


def _ffn_block(p: Params, h: jax.Array, cfg: TransformerConfig):
    x = nn.rms_norm({"scale": p["ffn_norm"]["scale"]}, h, cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    if cfg.moe is not None:
        y, aux = moe_ffn(p["moe"], x, cfg)
    elif cfg.activation == "swiglu":
        y = (jax.nn.silu(x @ p["w_gate"].astype(x.dtype))
             * (x @ p["w_up"].astype(x.dtype))) @ p["w_down"].astype(x.dtype)
    elif cfg.activation == "geglu":
        y = (jax.nn.gelu(x @ p["w_gate"].astype(x.dtype))
             * (x @ p["w_up"].astype(x.dtype))) @ p["w_down"].astype(x.dtype)
    elif cfg.activation == "relu2":
        u = jax.nn.relu(x @ p["w_up"].astype(x.dtype))
        y = (u * u) @ p["w_down"].astype(x.dtype)
    else:
        raise ValueError(cfg.activation)
    return h + y, aux


def _layer(p: Params, h, positions, mask, cfg, kv=None):
    h, new_kv = _attn_block(p, h, positions, mask, cfg, kv)
    h = _constrain_batch(h, cfg)
    h, aux = _ffn_block(p, h, cfg)
    h = _constrain_batch(h, cfg)
    return h, new_kv, aux


# ---------------------------------------------------------------------------
# forward passes
# ---------------------------------------------------------------------------

def forward(params: Params, tokens: jax.Array, cfg: TransformerConfig,
            return_cache: bool = False, last_only: bool = False):
    """Training/prefill forward. tokens: [B, S] -> logits [B, S, V] (fp32).

    ``last_only`` computes the LM head only for the final position (prefill
    serving: avoids materialising the [B, S, V] logits tensor).
    """
    dtype = compute_dtype(cfg)
    B, S = tokens.shape
    h = _constrain_batch(params["embed"].astype(dtype)[tokens], cfg)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    causal = (jnp.tril(jnp.ones((S, S), jnp.bool_))[None, None, :, :]
              if S <= _CHUNKED_ATTN_THRESHOLD else None)

    def body(h, layer_p):
        if return_cache:
            hh, (k, v), aux = _layer(layer_p, h, positions, causal, cfg)
            return hh, (aux, k, v)
        hh, _, aux = _layer(layer_p, h, positions, causal, cfg)
        return hh, aux

    if cfg.remat:
        body = jax.checkpoint(body)

    if cfg.scan_layers:
        h, ys = jax.lax.scan(body, h, params["layers"])
        if return_cache:
            aux, cache_k, cache_v = ys   # [L, ...]
        else:
            aux = ys
    else:
        auxs, ks, vs = [], [], []
        for lp in params["layers"]:
            h, y = body(h, lp)
            if return_cache:
                a, k, v = y
                auxs.append(a); ks.append(k); vs.append(v)
            else:
                auxs.append(y)
        aux = jnp.stack(auxs)
        if return_cache:
            cache_k, cache_v = jnp.stack(ks), jnp.stack(vs)

    h = nn.rms_norm(params["final_norm"], h, cfg.norm_eps)
    if last_only:
        h = h[:, -1:]
    head = (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"]).astype(dtype)
    logits = (h @ head).astype(jnp.float32)
    aux_loss = jnp.sum(aux)
    if return_cache:
        return logits, aux_loss, (cache_k, cache_v)
    return logits, aux_loss


def loss_fn(params: Params, tokens: jax.Array, labels: jax.Array,
            cfg: TransformerConfig):
    """Token-mean cross entropy + MoE aux losses."""
    logits, aux = forward(params, tokens, cfg)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    ce = jnp.mean(logz - gold)
    return ce + aux, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def init_cache(cfg: TransformerConfig, batch: int, max_len: int,
               dtype=None) -> Tuple[jax.Array, jax.Array]:
    dtype = dtype or compute_dtype(cfg)
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    return jnp.zeros(shape, dtype), jnp.zeros(shape, dtype)


def prefill(params: Params, tokens: jax.Array, cfg: TransformerConfig,
            last_only: bool = False):
    """Run the prompt; returns (logits, (cache_k, cache_v)) of prompt length."""
    logits, _, cache = forward(params, tokens, cfg, return_cache=True,
                               last_only=last_only)
    return logits, cache


def decode_step(params: Params, token: jax.Array, cache_k: jax.Array,
                cache_v: jax.Array, cache_len: jax.Array,
                cfg: TransformerConfig):
    """One decode step. token: [B, 1]; cache_[kv]: [L, B, T, KV, hd];
    cache_len: scalar int32 (tokens already in cache). Returns
    (logits [B, 1, V], new caches)."""
    dtype = compute_dtype(cfg)
    B = token.shape[0]
    T = cache_k.shape[2]
    h = params["embed"].astype(dtype)[token]                   # [B, 1, d]
    positions = jnp.full((B, 1), cache_len, jnp.int32)
    # attend to cache positions [0, cache_len]
    mask = (jnp.arange(T)[None, None, None, :] <= cache_len)   # [1,1,1,T]

    def body(h, xs):
        layer_p, ck, cv = xs                                   # ck: [B, T, KV, hd]
        hh, (nk, nv), _ = _layer(layer_p, h, positions, mask, cfg,
                                 kv=(ck, cv, cache_len))
        return hh, (nk, nv)

    h, (new_k, new_v) = jax.lax.scan(
        body, h, (params["layers"], cache_k, cache_v))
    h = nn.rms_norm(params["final_norm"], h, cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"]).astype(dtype)
    logits = (h @ head).astype(jnp.float32)
    return logits, new_k, new_v


def generate(params: Params, prompt: jax.Array, n_steps: int,
             cfg: TransformerConfig, temperature: float = 0.0,
             key=None):
    """Greedy/temperature sampling loop (host-driven, for examples/tests)."""
    B, S = prompt.shape
    max_len = S + n_steps
    logits, (pk, pv) = prefill(params, prompt, cfg)
    cache_k, cache_v = init_cache(cfg, B, max_len)
    cache_k = cache_k.at[:, :, :S].set(pk)
    cache_v = cache_v.at[:, :, :S].set(pv)
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(prompt.dtype)
    out = [tok]
    cache_len = jnp.int32(S)
    for i in range(n_steps - 1):
        logits, cache_k, cache_v = decode_step(
            params, tok, cache_k, cache_v, cache_len, cfg)
        if temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(
                sub, logits[:, -1] / temperature)[:, None].astype(prompt.dtype)
        else:
            tok = jnp.argmax(logits[:, -1:], axis=-1).astype(prompt.dtype)
        cache_len = cache_len + 1
        out.append(tok)
    return jnp.concatenate(out, axis=1)
