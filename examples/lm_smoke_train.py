"""Train a small (~10M-param reduced phi4-family) LM on synthetic tokens.

Shows the LM side of the framework on CPU: reduced --arch config, scan-over-
layers transformer, AdamW, gradient accumulation, checkpoint/restore, and a
serving sanity check (prefill + decode against the trained weights).

Run:  PYTHONPATH=src python examples/lm_smoke_train.py [--steps 60]
"""

import argparse
import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced_config
from repro.data.synthetic import TokenStream
from repro.models import transformer as T
from repro.training import optimizer as opt_mod
from repro.training import train_steps
from repro.training.trainer import TrainerConfig, TrainState, run


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    cfg = get_reduced_config("phi4-mini-3.8b")
    n_params = cfg.n_params()
    print(f"arch: {cfg.name}  params={n_params/1e6:.1f}M  "
          f"layers={cfg.n_layers} d={cfg.d_model}")

    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg, key)
    opt_cfg = opt_mod.OptimizerConfig(name="adamw", lr=3e-4)
    opt_state = opt_mod.init(opt_cfg, params)
    step = jax.jit(train_steps.lm_train_step(cfg, opt_cfg, grad_accum=2))

    data = TokenStream(cfg, args.batch, args.seq, seed=0)
    with tempfile.TemporaryDirectory() as ckpt_dir:
        tcfg = TrainerConfig(total_steps=args.steps, ckpt_every=20,
                             ckpt_dir=ckpt_dir, log_every=10)
        out = run(tcfg, step, TrainState(params, opt_state), data)
    losses = out["losses"]
    print(f"loss {losses[0]:.3f} -> {losses[-1]:.3f} over {len(losses)} steps")
    assert losses[-1] < losses[0], "loss did not decrease"

    # serving sanity: prefill a prompt, decode a few tokens greedily
    trained = out["state"].params
    prompt = jnp.asarray(
        np.random.default_rng(1).integers(0, cfg.vocab_size, (1, 16)),
        jnp.int32)
    logits, (ck, cv) = T.prefill(trained, prompt, cfg, last_only=True)
    tok = logits.argmax(-1).reshape(1, 1).astype(jnp.int32)
    # decode buffers: pad cache to prompt+8 slots
    pad = 8
    ck = jnp.pad(ck, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    cv = jnp.pad(cv, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    outs = []
    pos = jnp.int32(prompt.shape[1])
    for _ in range(pad):
        logits, ck, cv = T.decode_step(trained, tok, ck, cv, pos, cfg)
        tok = logits.argmax(-1).reshape(1, 1).astype(jnp.int32)
        outs.append(int(tok[0, 0]))
        pos = pos + 1
    print("greedy continuation:", outs)
    print("OK")


if __name__ == "__main__":
    main()
