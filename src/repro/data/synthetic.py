"""Synthetic data generators: token streams, GNN batches, DIN batches.

Deterministic (seeded) host-side generation sized by the arch's shape cell;
used by smoke tests, examples, and the end-to-end training drivers.
"""

from __future__ import annotations

from functools import partial
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import DINConfig, GNNConfig, TransformerConfig
from repro.core import b2sr as b2sr_mod
from repro.data import graphs as graph_gen
from repro.data.neighbor_sampler import sample, sampled_sizes
from repro.models.gnn.common import GraphBatch
from repro.models.recsys.din import DINBatch


# ---------------------------------------------------------------------------
# LM token streams
# ---------------------------------------------------------------------------

def lm_batch(cfg: TransformerConfig, batch: int, seq: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    tokens = rng.integers(0, cfg.vocab_size, (batch, seq + 1), dtype=np.int32)
    return (jnp.asarray(tokens[:, :-1]), jnp.asarray(tokens[:, 1:]))


class TokenStream:
    """Infinite deterministic token stream (the data pipeline for training)."""

    def __init__(self, cfg: TransformerConfig, batch: int, seq: int,
                 seed: int = 0):
        self.cfg, self.batch, self.seq, self.seed = cfg, batch, seq, seed
        self.step = 0

    def __iter__(self):
        return self

    def __next__(self):
        out = lm_batch(self.cfg, self.batch, self.seq,
                       seed=self.seed + self.step)
        self.step += 1
        return out

    def state(self):
        return {"step": self.step, "seed": self.seed}

    def restore(self, state):
        self.step = int(state["step"])
        self.seed = int(state["seed"])


# ---------------------------------------------------------------------------
# GNN batches
# ---------------------------------------------------------------------------

def full_graph_batch(cfg: GNNConfig, n_nodes: int, pattern: str = "hybrid",
                     seed: int = 0, with_b2sr: Optional[bool] = None,
                     coords: bool = False, generator=None) -> GraphBatch:
    rng = np.random.default_rng(seed)
    gen = generator if generator is not None else graph_gen.PATTERNS[pattern]
    rows, cols = gen(n_nodes, seed=seed)
    e = rows.shape[0]
    feat = rng.standard_normal((n_nodes, cfg.d_in)).astype(np.float32)
    labels = rng.integers(0, cfg.n_classes, n_nodes, dtype=np.int32)
    use_b2sr = cfg.use_b2sr if with_b2sr is None else with_b2sr
    ell = None
    deg = np.zeros(n_nodes, np.float32)
    np.add.at(deg, cols, 1.0)
    if use_b2sr:
        mat = b2sr_mod.coo_to_b2sr(cols, rows, n_nodes, n_nodes, cfg.tile_dim)
        ell = b2sr_mod.to_ell(mat)
    return GraphBatch(
        node_feat=jnp.asarray(feat),
        senders=jnp.asarray(rows.astype(np.int32)),
        receivers=jnp.asarray(cols.astype(np.int32)),
        node_mask=jnp.ones(n_nodes, bool),
        edge_mask=jnp.ones(e, bool),
        labels=jnp.asarray(labels),
        train_mask=jnp.asarray(rng.random(n_nodes) < 0.3),
        graph_ids=jnp.zeros(n_nodes, jnp.int32),
        n_graphs=1,
        coords=jnp.asarray(rng.standard_normal((n_nodes, 3)).astype(np.float32))
        if coords else None,
        edge_feat=None,
        ell=ell,
        degrees=jnp.asarray(deg + 1.0),
    )


def rmat_batch(cfg: GNNConfig, n_nodes: int, avg_degree: int = 8,
               seed: int = 0, with_b2sr: Optional[bool] = None,
               coords: bool = False) -> GraphBatch:
    """Power-law (R-MAT) full-graph batch — the skewed workload the
    bucketed-ELL path (DESIGN.md §2) is built for. Same contract as
    ``full_graph_batch(pattern="rmat")`` but with the degree knob exposed."""
    return full_graph_batch(
        cfg, n_nodes, seed=seed, with_b2sr=with_b2sr, coords=coords,
        generator=partial(graph_gen.rmat_graph, avg_degree=avg_degree))


def minibatch_batch(cfg: GNNConfig, n_total: int, batch_nodes: int,
                    fanout: Sequence[int] = (15, 10), seed: int = 0,
                    coords: bool = False) -> GraphBatch:
    """Neighbor-sampled subgraph batch (uses the real sampler)."""
    rng = np.random.default_rng(seed)
    rows, cols = graph_gen.dot_graph(n_total, density=min(20.0 / n_total, 0.01),
                                     seed=seed)
    order = np.argsort(rows)
    rows_s, cols_s = rows[order], cols[order]
    row_ptr = np.zeros(n_total + 1, np.int64)
    np.add.at(row_ptr, rows_s + 1, 1)
    row_ptr = np.cumsum(row_ptr)
    seeds = rng.choice(n_total, size=batch_nodes, replace=False)
    sub = sample(row_ptr, cols_s, seeds, fanout, seed=seed)
    n_pad = sub.node_ids.shape[0]
    feat = rng.standard_normal((n_pad, cfg.d_in)).astype(np.float32)
    labels = rng.integers(0, cfg.n_classes, n_pad, dtype=np.int32)
    return GraphBatch(
        node_feat=jnp.asarray(feat),
        senders=jnp.asarray(sub.senders),
        receivers=jnp.asarray(sub.receivers),
        node_mask=jnp.asarray(sub.node_mask),
        edge_mask=jnp.asarray(sub.edge_mask),
        labels=jnp.asarray(labels),
        train_mask=jnp.asarray(sub.seed_mask),
        graph_ids=jnp.zeros(n_pad, jnp.int32),
        n_graphs=1,
        coords=jnp.asarray(rng.standard_normal((n_pad, 3)).astype(np.float32))
        if coords else None,
    )


def molecule_batch(cfg: GNNConfig, n_graphs: int, nodes_per: int = 30,
                   edges_per: int = 64, seed: int = 0) -> GraphBatch:
    rng = np.random.default_rng(seed)
    N = n_graphs * nodes_per
    E = n_graphs * edges_per
    feat = rng.standard_normal((N, cfg.d_in)).astype(np.float32)
    offs = np.repeat(np.arange(n_graphs) * nodes_per, edges_per)
    snd = rng.integers(0, nodes_per, E) + offs
    rcv = rng.integers(0, nodes_per, E) + offs
    return GraphBatch(
        node_feat=jnp.asarray(feat),
        senders=jnp.asarray(snd.astype(np.int32)),
        receivers=jnp.asarray(rcv.astype(np.int32)),
        node_mask=jnp.ones(N, bool),
        edge_mask=jnp.ones(E, bool),
        labels=jnp.asarray(rng.integers(0, cfg.n_classes, n_graphs,
                                        dtype=np.int32)),
        train_mask=jnp.ones(N, bool),
        graph_ids=jnp.asarray(np.repeat(np.arange(n_graphs), nodes_per)
                              .astype(np.int32)),
        n_graphs=n_graphs,
        coords=jnp.asarray(rng.standard_normal((N, 3)).astype(np.float32)),
    )


# ---------------------------------------------------------------------------
# DIN batches
# ---------------------------------------------------------------------------

def din_batch(cfg: DINConfig, batch: int, seed: int = 0) -> DINBatch:
    rng = np.random.default_rng(seed)
    L = cfg.seq_len
    lens = rng.integers(1, L + 1, batch)
    mask = np.arange(L)[None, :] < lens[:, None]
    return DINBatch(
        hist_items=jnp.asarray(rng.integers(0, cfg.n_items, (batch, L),
                                            dtype=np.int32)),
        hist_cates=jnp.asarray(rng.integers(0, cfg.n_cates, (batch, L),
                                            dtype=np.int32)),
        hist_mask=jnp.asarray(mask),
        target_item=jnp.asarray(rng.integers(0, cfg.n_items, batch,
                                             dtype=np.int32)),
        target_cate=jnp.asarray(rng.integers(0, cfg.n_cates, batch,
                                             dtype=np.int32)),
        user_feats=jnp.asarray(rng.integers(0, cfg.user_feat_vocab,
                                            (batch, cfg.n_user_feats),
                                            dtype=np.int32)),
        labels=jnp.asarray(rng.integers(0, 2, batch).astype(np.float32)),
    )
