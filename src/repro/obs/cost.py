"""Kernel cost accounting: estimated FLOPs/bytes attached to cached plans.

Bit-GraphBLAS §VI attributes its wins kernel-by-kernel; to do that *online*
the serving stack needs to know, per cached plan, how much arithmetic and
HBM traffic one launch represents — then the launch-latency histograms in
the metrics registry turn directly into achieved FLOP/s and bytes/s per
(op, backend, tile_dim), comparable against the roofline.

The estimate reuses the hierarchical HLO cost model that already powers
the dry-run roofline (:mod:`repro.launch.hlo_cost`): when cost accounting
is enabled, a plan's first invocation AOT-lowers and compiles the jitted
loop (``fn.lower(*args).compile().as_text()``) and runs
:func:`~repro.launch.hlo_cost.analyze_hlo` over the optimized HLO — loop
trip counts and fusion boundaries included. The report lands on
``Plan.cost`` and as ``plan_flops`` / ``plan_hbm_bytes`` /
``plan_wire_bytes`` gauges in the registry.

Cost accounting is **off by default** (`set_cost_accounting(True)` to
enable): the AOT lowering is a second compile of the same program, which
is fine for benchmarks and analysis runs but not something the serving
hot path should pay implicitly. With it off, a plan's first call costs
exactly what it did before this module existed.
"""

from __future__ import annotations

from typing import Dict, List, Optional

from repro.obs import metrics as _metrics

__all__ = ["set_cost_accounting", "cost_accounting_enabled", "analyze_plan",
           "record_plan_cost", "roofline_table"]

_COST_ENABLED: List[bool] = [False]

#: Labels shared by the plan cost gauges and the launch latency histogram —
#: the join key of :func:`roofline_table`.
COST_LABELS = ("op", "backend", "tile_dim")


def set_cost_accounting(flag: bool) -> bool:
    """Enable/disable per-plan HLO cost analysis; returns previous value."""
    prev = _COST_ENABLED[0]
    _COST_ENABLED[0] = bool(flag)
    return prev


def cost_accounting_enabled() -> bool:
    return _COST_ENABLED[0] and _metrics.enabled()


def analyze_plan(fn, args, kwargs) -> Optional[dict]:
    """Cost-model one jitted plan callable against concrete example args.

    Returns ``hlo_cost.CostReport.as_dict()`` plus the measured AOT
    ``compile_s``, or None when the callable cannot be lowered (not a jit
    wrapper, tracing failure, …) — cost accounting must never break a
    launch, so every failure is swallowed into "no estimate".
    """
    import time

    from repro.launch.hlo_cost import analyze_hlo

    lower = getattr(fn, "lower", None)
    if lower is None:
        return None
    try:
        t0 = time.perf_counter()
        compiled = lower(*args, **kwargs).compile()
        compile_s = time.perf_counter() - t0
        report = analyze_hlo(compiled.as_text())
    except Exception:                        # noqa: BLE001 — best-effort model
        return None
    out = report.as_dict()
    out["compile_s"] = compile_s
    return out


def record_plan_cost(cost: dict, op: str, backend: str,
                     tile_dim: int,
                     registry: Optional[_metrics.MetricsRegistry] = None
                     ) -> None:
    """Publish one plan's cost estimate into the registry gauges."""
    if not _metrics.enabled():
        return
    reg = registry or _metrics.get_registry()
    labels = {"op": op, "backend": backend, "tile_dim": tile_dim}
    reg.gauge("plan_flops", "estimated FLOPs per launch (HLO cost model)",
              COST_LABELS).set(cost["flops"], **labels)
    reg.gauge("plan_hbm_bytes", "estimated HBM bytes per launch",
              COST_LABELS).set(cost["hbm_bytes"], **labels)
    reg.gauge("plan_wire_bytes", "estimated collective bytes per launch",
              COST_LABELS).set(cost["wire_bytes"], **labels)
    reg.histogram("plan_compile_s", "AOT compile time of cached plans",
                  COST_LABELS).observe(cost.get("compile_s", 0.0), **labels)


def roofline_table(registry: Optional[_metrics.MetricsRegistry] = None
                   ) -> List[dict]:
    """Join plan cost gauges with launch latency histograms: achieved rates.

    One row per (op, backend, tile_dim) that has both a cost estimate and
    observed launches: mean launch latency, estimated flops/bytes, and the
    achieved FLOP/s and HBM bytes/s those imply — the online version of
    the dry-run roofline fraction.
    """
    reg = registry or _metrics.get_registry()
    flops_g = reg.get("plan_flops")
    bytes_g = reg.get("plan_hbm_bytes")
    lat_h = reg.get("launch_latency_s")
    if flops_g is None or lat_h is None:
        return []
    # aggregate latency over the extra labels (bucketed/sharded) down to
    # the cost join key
    lat_by_key: Dict[tuple, List[float]] = {}
    for key, s in lat_h._series.items():
        labels = dict(zip(lat_h.labelnames, key))
        jk = tuple(labels.get(k, "") for k in COST_LABELS)
        lat_by_key.setdefault(jk, [0.0, 0])
        lat_by_key[jk][0] += s.sum
        lat_by_key[jk][1] += s.count
    # comm-volume counters from the sharded rows carry (op, backend,
    # shards) — aggregate over shard counts down to (op, backend) so the
    # gather-vs-exchange word totals land on every matching roofline row
    comm_by_key: Dict[tuple, Dict[str, float]] = {}
    for cname, col in (("gather_words_total", "gathered_words"),
                       ("exchange_words_total", "exchanged_words")):
        c = reg.get(cname)
        if c is None:
            continue
        for key, v in c._series.items():
            labels = dict(zip(c.labelnames, key))
            jk = (labels.get("op", ""), labels.get("backend", ""))
            comm_by_key.setdefault(jk, {})
            comm_by_key[jk][col] = comm_by_key[jk].get(col, 0.0) + float(v)
    rows: List[dict] = []
    for key in sorted(flops_g._series):
        labels = dict(zip(COST_LABELS, key))
        total_s, n = lat_by_key.get(key, (0.0, 0))
        if not n:
            continue
        mean_s = total_s / n
        flops = float(flops_g._series[key])
        hbm = float(bytes_g._series.get(key, 0.0)) if bytes_g else 0.0
        row = {
            **labels,
            "n_launches": n,
            "mean_latency_s": mean_s,
            "est_flops": flops,
            "est_hbm_bytes": hbm,
            "achieved_flops_s": flops / mean_s if mean_s else None,
            "achieved_hbm_bytes_s": hbm / mean_s if mean_s else None,
        }
        comm = comm_by_key.get((labels.get("op", ""),
                                labels.get("backend", "")))
        if comm:
            row.update(comm)
        rows.append(row)
    return rows
