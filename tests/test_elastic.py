"""Elastic rescale: a checkpoint written under one mesh restores onto
another (the node-failure / rescale recovery path). Subprocess with 8
devices: save sharded over 8, restore sharded over 4 and over 2×2."""

import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent("""
    import os, tempfile
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.training import checkpoint as ckpt

    tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
            "b": jnp.ones((8,)), "step": jnp.int32(7)}

    mesh8 = jax.make_mesh((8,), ("data",))
    sh8 = {"w": NamedSharding(mesh8, P("data", None)),
           "b": NamedSharding(mesh8, P("data")),
           "step": NamedSharding(mesh8, P())}
    placed = jax.tree_util.tree_map(jax.device_put, tree, sh8)

    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 5, placed)

        # restore onto a 4-device mesh (simulates losing half the slice)
        mesh4 = jax.make_mesh((4,), ("data",), devices=jax.devices()[:4])
        sh4 = {"w": NamedSharding(mesh4, P("data", None)),
               "b": NamedSharding(mesh4, P("data")),
               "step": NamedSharding(mesh4, P())}
        r4, _ = ckpt.restore(d, 5, tree, sh4)
        for k in tree:
            np.testing.assert_array_equal(np.asarray(tree[k]),
                                          np.asarray(r4[k]))
        assert r4["w"].sharding.mesh.devices.size == 4
        print("RESHARD_4_OK")

        # restore onto a 2x2 2-D mesh (different topology entirely)
        mesh22 = jax.make_mesh((2, 2), ("data", "model"),
                               devices=jax.devices()[:4])
        sh22 = {"w": NamedSharding(mesh22, P("data", "model")),
                "b": NamedSharding(mesh22, P(("data", "model"))),
                "step": NamedSharding(mesh22, P())}
        r22, _ = ckpt.restore(d, 5, tree, sh22)
        for k in tree:
            np.testing.assert_array_equal(np.asarray(tree[k]),
                                          np.asarray(r22[k]))
        print("RESHARD_2x2_OK")
""")


@pytest.fixture(scope="module")
def subprocess_run():
    return subprocess.run(
        [sys.executable, "-c", _SCRIPT], capture_output=True, text=True,
        timeout=300, env={**os.environ, "PYTHONPATH": "src"},
    )


@pytest.mark.parametrize("marker", ["RESHARD_4_OK", "RESHARD_2x2_OK"])
def test_elastic_reshard(subprocess_run, marker):
    assert subprocess_run.returncode == 0, subprocess_run.stderr[-2500:]
    assert marker in subprocess_run.stdout
