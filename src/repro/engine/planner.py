"""Launch-plan cache for the batched query engine (DESIGN.md §9).

A *plan* is a jit-compiled batched query loop specialised to one
(graph, kernel, batch width) combination: the closure captures the graph's
device arrays, so XLA constant-folds the operand layout, and the while-loop
is traced exactly once per plan. Serving traffic re-traces nothing — the
planner looks plans up by a :class:`PlanKey` built from

  - the graph's **structure fingerprint** (content hash of the ELL layout —
    two `GraphMatrix` wrappers around the same adjacency share plans),
  - the **kernel** name (msbfs / mskhop / ppr),
  - **backend**, **tile_dim**, and the **bucket layout** (per-bucket
    (rows, width) pairs — the bucketed dispatch bakes slab shapes into the
    trace, so a different bucketing is a different program),
  - the **padded batch width** (frontier columns after word padding; the
    batcher additionally quantises to powers of two so widths collapse to
    a handful of plan entries).

Eviction is LRU with a fixed capacity: serving fleets hold plans for the
hot graphs and let cold graph/width combinations fall out.
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Callable, Optional, Tuple

from repro.core.descriptor import Descriptor
from repro.core.graphblas import GraphMatrix


@dataclasses.dataclass(frozen=True)
class PlanKey:
    graph_fp: str
    kernel: str
    backend: str
    tile_dim: int
    bucket_layout: Optional[Tuple[Tuple[int, int], ...]]
    batch_width: int            # padded number of frontier columns (S_pad)
    # descriptor fields the traced loop bakes in (``descriptor_key``);
    # None for plans whose loop shape is fully named by ``kernel``
    desc: Optional[Tuple] = None
    # the mesh fingerprint for sharded graphs (``partition.mesh_fingerprint``:
    # axis names, shape, shard axes, member device ids) — a sharded plan's
    # shard_map trace bakes all of these in, so plans must never leak
    # across mesh shapes (or between sharded and unsharded execution, where
    # this field is None)
    mesh: Optional[Tuple] = None


def descriptor_key(desc: Descriptor,
                   masked: Optional[bool] = None) -> Tuple:
    """Hashable summary of the :class:`Descriptor` fields a plan bakes in.

    A traced query loop specialises on mask presence, complement,
    input-transpose, replace semantics, and row chunking — two loops
    differing in any of these are different XLA programs. ``masked``
    overrides mask presence for plans whose mask is loop-carried (built
    inside the loop, so not present on the descriptor at key time).
    """
    m = (desc.mask is not None) if masked is None else masked
    return (m, desc.complement, desc.transpose_a, desc.replace,
            desc.row_chunk, desc.direction)


@dataclasses.dataclass
class Plan:
    """A cached, jit-compiled batched query loop."""

    key: PlanKey
    fn: Callable
    n_calls: int = 0

    def __call__(self, *args, **kw):
        self.n_calls += 1
        return self.fn(*args, **kw)


def plan_key(g: GraphMatrix, kernel: str, batch_width: int,
             desc: Optional[Tuple] = None) -> PlanKey:
    """Build the cache key for ``kernel`` on ``g`` at ``batch_width``.

    ``desc`` is a :func:`descriptor_key` tuple for loops parameterised by
    a Descriptor (mask presence / complement / replace / chunking).
    Sharded graphs contribute their mesh fingerprint, so one serving
    process can hold plans for several meshes (and for the unsharded twin)
    without cross-talk.
    """
    bucket_layout = None
    if g.backend != "csr" and g.use_buckets:
        b = g.buckets()
        bucket_layout = tuple(zip(b.bucket_sizes, b.bucket_widths))
    mesh_fp = None
    if g.sharded:
        from repro.core.partition import mesh_fingerprint
        mesh_fp = mesh_fingerprint(g.mesh, g.shard_axes)
    return PlanKey(
        graph_fp=g.fingerprint(), kernel=kernel, backend=g.backend,
        tile_dim=g.tile_dim, bucket_layout=bucket_layout,
        batch_width=batch_width, desc=desc, mesh=mesh_fp)


class PlanCache:
    """LRU cache of :class:`Plan` objects with hit/miss/eviction counters."""

    def __init__(self, capacity: int = 32):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self._plans: "OrderedDict[PlanKey, Plan]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def get(self, key: PlanKey, builder: Callable[[], Callable]) -> Plan:
        """The plan for ``key``, building (and possibly evicting) on miss."""
        plan = self._plans.get(key)
        if plan is not None:
            self._plans.move_to_end(key)
            self.hits += 1
            return plan
        self.misses += 1
        plan = Plan(key=key, fn=builder())
        self._plans[key] = plan
        while len(self._plans) > self.capacity:
            self._plans.popitem(last=False)
            self.evictions += 1
        return plan

    def __len__(self) -> int:
        return len(self._plans)

    def __contains__(self, key: PlanKey) -> bool:
        return key in self._plans

    def keys(self):
        return list(self._plans.keys())

    def clear(self) -> None:
        self._plans.clear()
        self.hits = self.misses = self.evictions = 0


# The module-level cache that GraphMatrix entry points and the batcher use;
# pass an explicit PlanCache to engine.queries for isolated lifetimes.
DEFAULT_PLANNER = PlanCache()
