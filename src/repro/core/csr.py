"""Plain float CSR/COO matrix — the GraphBLAST/cuSPARSE baseline substrate.

The paper compares B2SR against CSR with fp32 values. In JAX the idiomatic
CSR-SpMV is a gather + ``segment_sum`` over edges; we keep an explicit COO
row-index array alongside CSR pointers so both layouts are available.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.b2sr import _pytree, static_field
from repro.core.semiring import Semiring, ARITHMETIC


@_pytree
@dataclasses.dataclass(frozen=True)
class CSRMatrix:
    row_ptr: jax.Array   # int32[n_rows + 1]
    col_idx: jax.Array   # int32[nnz]
    row_idx: jax.Array   # int32[nnz] (COO twin of row_ptr, for segment ops)
    values: jax.Array    # float32[nnz]
    n_rows: int = static_field()
    n_cols: int = static_field()

    @property
    def nnz(self) -> int:
        return int(self.col_idx.shape[0])

    def storage_bytes(self, value_bytes: int = 4) -> int:
        return 4 * (self.n_rows + 1) + 4 * self.nnz + value_bytes * self.nnz


def from_coo(rows: np.ndarray, cols: np.ndarray, n_rows: int, n_cols: int,
             values: np.ndarray | None = None) -> CSRMatrix:
    rows = np.asarray(rows, dtype=np.int64)
    cols = np.asarray(cols, dtype=np.int64)
    order = np.argsort(rows * n_cols + cols, kind="stable")
    rows, cols = rows[order], cols[order]
    if values is None:
        vals = np.ones(rows.shape[0], dtype=np.float32)
    else:
        vals = np.asarray(values, dtype=np.float32)[order]
    # de-duplicate (binary OR semantics: keep first)
    if rows.size:
        key = rows * n_cols + cols
        keep = np.concatenate([[True], key[1:] != key[:-1]])
        rows, cols, vals = rows[keep], cols[keep], vals[keep]
    ptr = np.zeros(n_rows + 1, dtype=np.int64)
    np.add.at(ptr, rows + 1, 1)
    ptr = np.cumsum(ptr).astype(np.int32)
    return CSRMatrix(
        row_ptr=jnp.asarray(ptr),
        col_idx=jnp.asarray(cols.astype(np.int32)),
        row_idx=jnp.asarray(rows.astype(np.int32)),
        values=jnp.asarray(vals),
        n_rows=n_rows,
        n_cols=n_cols,
    )


def to_dense(m: CSRMatrix) -> np.ndarray:
    out = np.zeros((m.n_rows, m.n_cols), dtype=np.float32)
    out[np.asarray(m.row_idx), np.asarray(m.col_idx)] = np.asarray(m.values)
    return out


def mxv(m: CSRMatrix, x: jax.Array, semiring: Semiring = ARITHMETIC,
        a_value: float | None = None) -> jax.Array:
    """y_i = ⊕_j A_ij ⊗ x_j over edges (segment reduce by destination row).

    ``a_value`` overrides the stored edge values with a uniform value (parity
    with the binary-matrix B2SR path, whose edges carry no values).
    """
    vals = (m.values.astype(x.dtype) if a_value is None
            else jnp.full_like(m.values, a_value, dtype=x.dtype))
    prod = semiring.mul(vals, x[m.col_idx])
    if semiring.add is jnp.add:
        return jax.ops.segment_sum(prod, m.row_idx, num_segments=m.n_rows)
    if semiring.add is jnp.minimum:
        return jax.ops.segment_min(prod, m.row_idx, num_segments=m.n_rows,
                                   indices_are_sorted=True)
    if semiring.add is jnp.maximum:
        return jax.ops.segment_max(prod, m.row_idx, num_segments=m.n_rows,
                                   indices_are_sorted=True)
    if semiring.add is jnp.logical_or:
        hit = jax.ops.segment_max(prod.astype(jnp.int32), m.row_idx,
                                  num_segments=m.n_rows, indices_are_sorted=True)
        return hit > 0
    raise NotImplementedError(semiring.name)


def spmm(m: CSRMatrix, x: jax.Array) -> jax.Array:
    """Y = A @ X for dense X [n_cols, d] (arithmetic semiring)."""
    gathered = x[m.col_idx] * m.values[:, None].astype(x.dtype)
    return jax.ops.segment_sum(gathered, m.row_idx, num_segments=m.n_rows)


def mxv_masked(m: CSRMatrix, x: jax.Array, mask: jax.Array,
               semiring: Semiring = ARITHMETIC, complement: bool = False,
               a_value: float | None = None) -> jax.Array:
    """Masked mxv: output elements where mask (or ~mask) is 0 are ⊕-identity."""
    y = mxv(m, x, semiring, a_value)
    keep = (mask == 0) if complement else (mask != 0)
    ident = semiring.identity_for(y.dtype) if y.dtype != jnp.bool_ else False
    return jnp.where(keep, y, ident)
