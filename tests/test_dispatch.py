"""Unified-API tests: typed operands, descriptor semantics, the dispatch
registry, and the legacy-shim deprecation contract (ISSUE 4, DESIGN.md §10).

Covers:
  - descriptor semantics: transpose × mask × complement × replace
    combinations, checked against hand-computed references,
  - parity of every generic op across all 3 backends × buckets on/off,
  - registry completeness: every registered key resolves, every public op
    resolves through the registry,
  - the legacy method shims: external callers get the old behavior plus a
    ``GraphBLASDeprecationWarning``; repro-internal callers raise.
"""

from __future__ import annotations

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import dispatch
from repro.core.b2sr import pack_bitvector, unpack_bitvector
from repro.core.descriptor import DEFAULT, Descriptor, merge_sugar
from repro.core.graphblas import BACKENDS, GraphMatrix, LowerTriangle
from repro.core.operands import BitVector, FrontierBatch, operand_kind
from repro.core.semiring import ARITHMETIC, BOOLEAN, MIN_PLUS

SETUPS = [(b, u) for b in BACKENDS for u in (False, True)]


def build(n=48, t=8, density=0.15, seed=3, backend="b2sr", use_buckets=True):
    rng = np.random.RandomState(seed)
    d = (rng.random((n, n)) < density).astype(np.uint8)
    g = GraphMatrix.from_dense(d, tile_dim=t, backend=backend)
    return g.with_buckets(use_buckets), d


def rand_vec(n, seed=7):
    return jnp.asarray(np.random.RandomState(seed).rand(n).astype(np.float32))


# ---------------------------------------------------------------------------
# typed operands
# ---------------------------------------------------------------------------

def test_operand_kinds():
    g, _ = build()
    x = rand_vec(48)
    bv = BitVector.pack(x > 0.5, 8)
    fb = FrontierBatch.pack(jnp.stack([x > 0.5, x > 0.2], 1), 8)
    assert operand_kind(x) == "dense"
    assert operand_kind(bv) == "bitvec"
    assert operand_kind(fb) == "frontier"
    assert operand_kind(g) == "graph"


def test_bitvector_roundtrip_and_algebra():
    x = np.random.RandomState(0).rand(50) > 0.5
    a = BitVector.pack(jnp.asarray(x), 8)
    b = BitVector.pack(jnp.asarray(~x), 8)
    assert a.n == 50 and a.tile_dim == 8
    assert np.array_equal(np.asarray(a.unpack(jnp.bool_)), x)
    assert bool((a | b).any())
    assert np.asarray((a & b).unpack(jnp.bool_)).sum() == 0
    # ~ flips pad bits too, but unpack drops them
    assert np.array_equal(np.asarray((~a).unpack(jnp.bool_))[:50], ~x)


def test_frontier_batch_roundtrip():
    x = np.random.RandomState(1).rand(40, 5) > 0.6
    f = FrontierBatch.pack(jnp.asarray(x), 8)
    assert f.n == 40 and f.n_sources == 5 and f.padded_width == 32
    assert np.array_equal(np.asarray(f.unpack(jnp.bool_)), x)


def test_wrong_operand_types_raise():
    g, _ = build()
    bv = BitVector.pack(rand_vec(48) > 0.5, 8)
    fb = FrontierBatch.pack(jnp.zeros((48, 2)), 8)
    with pytest.raises(TypeError):
        g.mxv(fb)                         # frontier operand belongs to mxm
    with pytest.raises(TypeError):
        g.mxm(bv)                         # packed vector belongs to mxv
    with pytest.raises(ValueError):
        g.mxv(BitVector.pack(rand_vec(48) > 0.5, 4))   # tile_dim mismatch


# ---------------------------------------------------------------------------
# descriptor semantics: transpose × mask × complement × replace
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("transpose", [False, True])
@pytest.mark.parametrize("complement", [False, True])
@pytest.mark.parametrize("replace", [False, True])
def test_descriptor_combinations_dense(transpose, complement, replace):
    g, d = build()
    n = 48
    x = rand_vec(n)
    mask = jnp.asarray((np.arange(n) % 3 == 0).astype(np.float32))
    prev = jnp.full((n,), 99.0, jnp.float32)
    ref = jnp.asarray((d.T if transpose else d) @ np.asarray(x))
    keep = (mask == 0) if complement else (mask != 0)
    want = jnp.where(keep, ref, 0.0 if replace else prev)
    desc = Descriptor(mask=mask, complement=complement, replace=replace,
                      transpose_a=transpose)
    got = g.mxv(x, ARITHMETIC, desc, out=None if replace else prev)
    assert np.allclose(np.asarray(got), np.asarray(want), atol=1e-5)


@pytest.mark.parametrize("transpose", [False, True])
@pytest.mark.parametrize("complement", [False, True])
@pytest.mark.parametrize("replace", [False, True])
def test_descriptor_combinations_packed(transpose, complement, replace):
    g, d = build()
    n, t = 48, 8
    rng = np.random.RandomState(11)
    x = BitVector.pack(jnp.asarray(rng.rand(n) > 0.5), t)
    mask = BitVector.pack(jnp.asarray(rng.rand(n) > 0.5), t)
    prev = BitVector.pack(jnp.asarray(np.ones(n)), t)
    a = d.T if transpose else d
    ref = (a @ np.asarray(x.unpack())) > 0
    mk = np.asarray(mask.unpack(jnp.bool_))
    keep = ~mk if complement else mk
    want = ref & keep
    if not replace:
        want = want | (np.asarray(prev.unpack(jnp.bool_)) & ~keep)
    desc = Descriptor(mask=mask, complement=complement, replace=replace,
                      transpose_a=transpose)
    got = g.mxv(x, BOOLEAN, desc, out=None if replace else prev)
    assert np.array_equal(np.asarray(got.unpack(jnp.bool_)), want)


def test_replace_false_requires_out():
    g, _ = build()
    x = rand_vec(48)
    desc = Descriptor(mask=x > 0.5, replace=False)
    with pytest.raises(ValueError, match="out="):
        g.mxv(x, ARITHMETIC, desc)


def test_sugar_kwargs_fold_into_descriptor():
    g, d = build()
    x = rand_vec(48)
    mask = x > 0.3
    a = np.asarray(g.mxv(x, ARITHMETIC, mask=mask, complement=True))
    b = np.asarray(g.mxv(x, ARITHMETIC,
                         Descriptor(mask=mask, complement=True)))
    assert np.array_equal(a, b)
    with pytest.raises(ValueError, match="not both"):
        g.mxv(x, ARITHMETIC, Descriptor(mask=mask), mask=mask)
    assert merge_sugar(None) is DEFAULT


def test_vxm_accepts_sugar_kwargs():
    g, d = build()
    x = rand_vec(48)
    mask = x > 0.4
    got = g.vxm(x, ARITHMETIC, mask=mask, complement=True)
    want = g.transposed().mxv(x, ARITHMETIC, mask=mask, complement=True)
    assert np.allclose(np.asarray(got), np.asarray(want), atol=1e-6)


def test_mxm_dense_vector_mask_masks_rows():
    # a 1-D (or BitVector) mask over the [n, d] feature output masks rows —
    # it must broadcast along d, not collide with it
    g, d = build()
    n = 48
    X = jnp.asarray(np.random.RandomState(13).rand(n, 5).astype(np.float32))
    keep = np.arange(n) % 2 == 0
    want = np.where(keep[:, None], np.asarray(d, np.float32) @ np.asarray(X),
                    0.0)
    for mask in (jnp.asarray(keep.astype(np.float32)),
                 BitVector.pack(jnp.asarray(keep), 8)):
        got = g.mxm(X, mask=mask)
        assert np.allclose(np.asarray(got), want, atol=1e-5)
    # d == n must not silently mask columns instead of rows
    Xn = jnp.asarray(np.random.RandomState(14).rand(n, n).astype(np.float32))
    got = g.mxm(Xn, mask=jnp.asarray(keep.astype(np.float32)))
    wantn = np.where(keep[:, None],
                     np.asarray(d, np.float32) @ np.asarray(Xn), 0.0)
    assert np.allclose(np.asarray(got), wantn, atol=1e-4)


def test_unhonorable_semirings_raise():
    # packed / widened rows hard-code their reduction: any semiring the
    # row cannot honor must raise, never be reinterpreted as counts
    g, _ = build()
    bv = BitVector.pack(rand_vec(48) > 0.5, 8)
    fb = FrontierBatch.pack(jnp.zeros((48, 2)), 8)
    X = rand_vec(48)[:, None]
    with pytest.raises(NotImplementedError, match="semiring"):
        g.mxv(bv, MIN_PLUS)
    with pytest.raises(NotImplementedError, match="semiring"):
        g.mxm(X, MIN_PLUS)
    with pytest.raises(NotImplementedError, match="semiring"):
        g.mxm(fb, ARITHMETIC)
    with pytest.raises(NotImplementedError, match="semiring"):
        g.mxm(g, MIN_PLUS)


def test_vxm_is_transpose_descriptor():
    g, _ = build()
    x = rand_vec(48)
    assert np.allclose(
        np.asarray(g.vxm(x)),
        np.asarray(g.mxv(x, desc=Descriptor(transpose_a=True))), atol=1e-6)
    assert np.allclose(np.asarray(g.vxm(x)),
                       np.asarray(g.transposed().mxv(x)), atol=1e-6)


def test_mxm_graph_replace_merge():
    g, d = build()
    m = g.mxm(g, mask=g, complement=True)          # masked SpGEMM, replace
    prev = g                                       # previous output C = A
    got = g.mxm(g, desc=Descriptor(mask=g, complement=True, replace=False),
                out=prev)
    d2 = (d.astype(np.int64) @ d.astype(np.int64)) > 0
    keep = ~(d > 0)
    want = (d2 & keep) | ((d > 0) & ~keep)         # masked-out from prev
    from repro.core.b2sr import b2sr_to_dense, coo_to_b2sr
    got_d = b2sr_to_dense(coo_to_b2sr(
        np.asarray(got.csr.row_idx), np.asarray(got.csr.col_idx),
        48, 48, 8)) > 0
    assert np.array_equal(got_d, want)
    # and the replace=True result is the masked product alone
    m_d = b2sr_to_dense(coo_to_b2sr(
        np.asarray(m.csr.row_idx), np.asarray(m.csr.col_idx), 48, 48, 8)) > 0
    assert np.array_equal(m_d, d2 & keep)


# ---------------------------------------------------------------------------
# backend × bucket parity for every generic op row
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend,use_buckets", SETUPS)
def test_parity_mxv_rows(backend, use_buckets):
    g, d = build(backend=backend, use_buckets=use_buckets)
    ref, _ = build(backend="csr")
    n, t = 48, 8
    rng = np.random.RandomState(5)
    x = jnp.asarray(rng.rand(n).astype(np.float32))
    bv = BitVector.pack(jnp.asarray(rng.rand(n) > 0.5), t)
    mask = BitVector.pack(jnp.asarray(rng.rand(n) > 0.5), t)
    dmask = jnp.asarray((rng.rand(n) > 0.5).astype(np.float32))
    # dense full (arithmetic + min-plus), masked and unmasked
    assert np.allclose(np.asarray(g.mxv(x)), np.asarray(ref.mxv(x)),
                       atol=1e-5)
    assert np.allclose(np.asarray(g.mxv(x, MIN_PLUS)),
                       np.asarray(ref.mxv(x, MIN_PLUS)), atol=1e-6)
    assert np.allclose(
        np.asarray(g.mxv(x, ARITHMETIC, mask=dmask, complement=True)),
        np.asarray(ref.mxv(x, ARITHMETIC, mask=dmask, complement=True)),
        atol=1e-5)
    # packed boolean, masked and unmasked
    assert np.array_equal(np.asarray(g.mxv(bv).words),
                          np.asarray(ref.mxv(bv).words))
    got = g.mxv(bv, desc=Descriptor(mask=mask, complement=True))
    want = ref.mxv(bv, desc=Descriptor(mask=mask, complement=True))
    assert np.array_equal(np.asarray(got.words), np.asarray(want.words))
    # packed counts
    assert np.array_equal(
        np.asarray(g.mxv(bv, ARITHMETIC, out_dtype=jnp.int32)),
        np.asarray(ref.mxv(bv, ARITHMETIC, out_dtype=jnp.int32)))


@pytest.mark.parametrize("backend,use_buckets", SETUPS)
def test_parity_mxm_rows(backend, use_buckets):
    g, d = build(backend=backend, use_buckets=use_buckets)
    ref, _ = build(backend="csr")
    n, t = 48, 8
    rng = np.random.RandomState(6)
    X = jnp.asarray(rng.rand(n, 5).astype(np.float32))
    fb = FrontierBatch.pack(jnp.asarray(rng.rand(n, 3) > 0.5), t)
    fmask = FrontierBatch.pack(jnp.asarray(rng.rand(n, 3) > 0.5), t)
    # dense features (the GNN row)
    assert np.allclose(np.asarray(g.mxm(X)), np.asarray(ref.mxm(X)),
                       atol=1e-4)
    # frontier batch, masked and unmasked
    assert np.array_equal(np.asarray(g.mxm(fb).unpack(jnp.bool_)),
                          np.asarray(ref.mxm(fb).unpack(jnp.bool_)))
    got = g.mxm(fb, desc=Descriptor(mask=fmask, complement=True))
    want = ref.mxm(fb, desc=Descriptor(mask=fmask, complement=True))
    assert np.array_equal(np.asarray(got.unpack(jnp.bool_)),
                          np.asarray(want.unpack(jnp.bool_)))
    # boolean SpGEMM + count SpGEMM (+ masked)
    for kw in ({}, {"mask": g, "complement": True}):
        a = g.mxm(g, **kw)
        b = ref.mxm(ref, **kw)
        assert a.nnz == b.nnz
        assert np.array_equal(np.asarray(a.csr.col_idx),
                              np.asarray(b.csr.col_idx))
        ca = np.asarray(g.mxm(g, ARITHMETIC, **kw))
        cb = np.asarray(ref.mxm(ref, ARITHMETIC, **kw))
        assert np.array_equal(ca, cb)
    # fused masked sum (tri_count)
    assert float(g.tri_count()) == float(ref.tri_count())


# ---------------------------------------------------------------------------
# registry completeness + every public op resolves through the registry
# ---------------------------------------------------------------------------

def test_every_registered_key_resolves():
    keys = dispatch.registered_keys(load_all=True)
    assert len(keys) >= 60          # 3 backends x the Table II/III rows
    for op, rhs, out, backend, bucketed, masked, sharded in keys:
        fn = dispatch.resolve(op, rhs, out, backend, bucketed, masked,
                              sharded)
        assert callable(fn)
    # the full (bucketed x masked) square is registered for every
    # (op, rhs, out, backend, sharded) combination that exists at all —
    # except the masked-only ops (mxm_sum and the pull traversal rows,
    # which have no unmasked semantics; dispatch.MASKED_ONLY_OPS)
    groups = {(k[:4], k[6]) for k in keys}
    for quad, sharded in groups:
        flags = {k[4:6] for k in keys if k[:4] == quad and k[6] == sharded}
        want = ({(b, True) for b in (False, True)}
                if quad[0] in dispatch.MASKED_ONLY_OPS else
                {(b, m) for b in (False, True) for m in (False, True)})
        assert flags == want, (f"incomplete flag square for {quad} "
                               f"sharded={sharded}: {flags}")
    # sharded rows exist for the b2sr backends only (ISSUE 5): the shard_map
    # twins register for both bit backends, the csr baseline for neither
    sharded_backends = {k[3] for k in keys if k[6]}
    assert sharded_backends == {"b2sr", "b2sr_pallas"}


def test_unregistered_key_raises():
    with pytest.raises(NotImplementedError, match="no kernel registered"):
        dispatch.resolve("mxv", "frontier", "bin", "b2sr", False, False)
    # no sharded rows for the csr baseline — and the error says what to do
    with pytest.raises(NotImplementedError, match="unshard"):
        dispatch.resolve("mxv", "dense", "full", "csr", False, False, True)


@pytest.mark.parametrize("backend", BACKENDS)
def test_public_ops_hit_registry(backend):
    g, _ = build(backend=backend)
    n, t = 48, 8
    x = rand_vec(n)
    bv = BitVector.pack(x > 0.5, t)
    fb = FrontierBatch.pack(jnp.stack([x > 0.5, x > 0.2], 1), t)
    ops = [
        (lambda: g.mxv(x), ("mxv", "dense", "full")),
        (lambda: g.mxv(bv), ("mxv", "bitvec", "bin")),
        (lambda: g.mxv(bv, ARITHMETIC), ("mxv", "bitvec", "full")),
        (lambda: g.mxm(x[:, None]), ("mxm", "dense", "full")),
        (lambda: g.mxm(fb), ("mxm", "frontier", "bin")),
        (lambda: g.mxm(g), ("mxm", "graph", "bin")),
        (lambda: g.mxm(g, ARITHMETIC), ("mxm", "graph", "full")),
        (lambda: g.tri_count(), ("mxm_sum", "tri", "full")),
    ]
    for fn, row in ops:
        before = dispatch.stats["resolves"]
        fn()
        assert dispatch.stats["resolves"] > before, f"{row} skipped registry"
        assert dispatch.last_key[:3] == row
        assert dispatch.last_key[3] == backend


# ---------------------------------------------------------------------------
# legacy shims: deprecation contract + bit-identical outputs
# ---------------------------------------------------------------------------

def test_shims_warn_and_match_new_api():
    g, _ = build()
    n, t = 48, 8
    rng = np.random.RandomState(9)
    x = jnp.asarray(rng.rand(n).astype(np.float32))
    xw = pack_bitvector(x > 0.5, t, n)
    mw = pack_bitvector(jnp.asarray(rng.rand(n) > 0.5), t, n)
    X = jnp.asarray(rng.rand(n, 3).astype(np.float32))
    fw = FrontierBatch.pack(jnp.asarray(rng.rand(n, 3) > 0.5), t).words
    bv = BitVector.from_words(xw, n, t)
    mask = BitVector.from_words(mw, n, t)
    cases = [
        (lambda: g.mxv_bool(xw, mw),
         lambda: g.mxv(bv, desc=Descriptor(mask=mask,
                                           complement=True)).words),
        (lambda: g.mxv_count(xw, jnp.int32),
         lambda: g.mxv(bv, ARITHMETIC, out_dtype=jnp.int32)),
        (lambda: g.spmm(X), lambda: g.mxm(X)),
        (lambda: g.spmm_bool(fw),
         lambda: g.mxm(FrontierBatch.from_words(fw, n, 32, t)).words),
        (lambda: g.mxm_count(g), lambda: g.mxm(g, ARITHMETIC)),
    ]
    for legacy, new in cases:
        with pytest.warns(dispatch.GraphBLASDeprecationWarning):
            old = legacy()
        assert np.array_equal(np.asarray(old), np.asarray(new()))


def test_shims_raise_for_repro_internal_callers():
    g, _ = build()
    xw = pack_bitvector(rand_vec(48) > 0.5, 8, 48)
    ns = {"__name__": "repro.fake_module"}
    exec("def call_shim(g, xw):\n    return g.mxv_bool(xw)", ns)
    with pytest.raises(RuntimeError, match="repro-internal"):
        ns["call_shim"](g, xw)


# ---------------------------------------------------------------------------
# satellites: with_backend validation + tri_count memoization
# ---------------------------------------------------------------------------

def test_with_backend_validates():
    g, _ = build()
    with pytest.raises(ValueError, match="backend must be one of"):
        g.with_backend("cuda")
    assert g.with_backend("csr").backend == "csr"


def test_tri_lower_triangle_memoized():
    n = 40
    rng = np.random.RandomState(4)
    d = (rng.random((n, n)) < 0.2).astype(np.uint8)
    d = np.triu(d, 1)
    d = d | d.T                                    # symmetric, no diagonal
    g = GraphMatrix.from_dense(d, tile_dim=8)
    assert g.tri_cache is None
    first = float(g.tri_count())
    cache = g.tri_cache
    assert isinstance(cache, LowerTriangle)
    assert float(g.tri_count()) == first
    assert g.tri_cache is cache                    # rebuilt nothing
    # the cache survives backend switches (operands are format-level)...
    gp = g.with_backend("b2sr_pallas")
    assert gp.tri_cache is cache
    assert float(gp.tri_count()) == first
    # ...and matches the CSR baseline, which never builds the ELL pair
    gc = GraphMatrix.from_dense(d, tile_dim=8, backend="csr")
    assert float(gc.tri_count()) == first
    assert gc.tri_cache._ell is None               # lazy: csr stayed dense
    # the transposed view gets its own lower triangle
    assert g.transposed().tri_cache is None


def test_unpack_bitvector_matches_operand_unpack():
    x = np.random.RandomState(2).rand(30) > 0.5
    bv = BitVector.pack(jnp.asarray(x), 8)
    assert np.array_equal(
        np.asarray(unpack_bitvector(bv.words, 8, 30, jnp.bool_)),
        np.asarray(bv.unpack(jnp.bool_)))
