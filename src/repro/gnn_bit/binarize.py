"""Binarization for BitGNN layers: STE, α scales, activation packing.

Training-side: ``ste_sign`` / ``ste_step`` are the clipped straight-through
estimators (Bengio et al.; XNOR-Net) — forward is the hard quantizer,
backward passes the upstream gradient through wherever ``|x| <= 1`` and
zeroes it outside (the saturation clip that keeps weights from drifting
forever past the threshold).

Inference-side: ``pack_activations`` bit-packs a binarized activation
matrix into :class:`~repro.core.operands.BitMatrix` words through the
Pallas packing kernel (``kernels/bitpack``), and ``alpha_scale`` computes
the per-feature reconstruction scale α_j = mean|x_j| so that
``α · (A @ bits)`` approximates ``A @ x`` (exact when x is already
binary; XNOR-style otherwise).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.operands import BitMatrix
from repro.kernels.bitpack import ops as bitpack_ops


@jax.custom_vjp
def ste_sign(x: jax.Array) -> jax.Array:
    """Hard ±1 quantizer with a clipped straight-through gradient."""
    return jnp.where(x >= 0, 1.0, -1.0).astype(x.dtype)


def _ste_sign_fwd(x):
    return ste_sign(x), x


def _ste_clip_bwd(x, g):
    return (g * (jnp.abs(x) <= 1.0).astype(g.dtype),)


ste_sign.defvjp(_ste_sign_fwd, _ste_clip_bwd)


@jax.custom_vjp
def ste_step(x: jax.Array) -> jax.Array:
    """Hard {0, 1} threshold (x > 0) with the same clipped STE gradient."""
    return (x > 0).astype(x.dtype)


def _ste_step_fwd(x):
    return ste_step(x), x


ste_step.defvjp(_ste_step_fwd, _ste_clip_bwd)


def alpha_scale(x: jax.Array, axis: int = 0) -> jax.Array:
    """Per-feature reconstruction scale α = mean|x| along ``axis``."""
    return jnp.mean(jnp.abs(x), axis=axis)


def binarize(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """(±1 STE binarization of ``x``, per-feature α) — the XNOR pair.

    ``xb * alpha[None, :]`` is the rank-1 reconstruction of ``x`` that the
    bit aggregation path computes implicitly via α·popcount.
    """
    return ste_sign(x), alpha_scale(x)


def pack_activations(x: jax.Array, tile_dim: int,
                     interpret: Optional[bool] = None) -> BitMatrix:
    """Binarize (``x > 0``) and bit-pack activations into BitMatrix words.

    Runs through the Pallas row-packing kernel; traceable, so jitted
    forwards (and serving plans) can pack per layer. Note the threshold is
    strict — for ±1 inputs the 1-bits are exactly the +1 entries, which is
    what the ``2·counts − rowsum`` reconstruction in ``layers`` assumes.
    """
    words = bitpack_ops.pack_columns(x > 0, tile_dim, interpret=interpret)
    return BitMatrix.from_words(words, int(x.shape[0]), tile_dim)
