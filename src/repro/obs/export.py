"""Exporters: metrics files (JSON / Prometheus text) and trace JSONL.

The registry is pull-based — nothing in the serving stack pushes to a
collector; exporters serialise a snapshot when somebody asks (a CI
artifact step, the ``--metrics-out`` flag on the serving driver, a test).
``parse_prometheus`` exists so the text format is round-trippable and
therefore testable, not as a scraping client.
"""

from __future__ import annotations

import json
import re
from typing import Dict, Optional

from repro.obs import metrics as _metrics

__all__ = ["write_metrics", "parse_prometheus"]

#: One exposition line: name, optional {label="v",...} block, value.
_SAMPLE_RE = re.compile(
    r'^([A-Za-z_:][\w:]*)(\{[^}]*\})?\s+(\S+)\s*$')
_LABEL_RE = re.compile(r'([A-Za-z_][\w]*)="([^"]*)"')


def write_metrics(path: str,
                  registry: Optional[_metrics.MetricsRegistry] = None
                  ) -> str:
    """Write a registry snapshot to ``path``; format follows the extension
    (``.prom`` / ``.txt`` → Prometheus text, anything else → JSON).
    Returns the path."""
    reg = registry or _metrics.get_registry()
    if path.endswith((".prom", ".txt")):
        payload = reg.to_prometheus()
    else:
        payload = json.dumps(reg.snapshot(), indent=1, sort_keys=True,
                             default=str)
    with open(path, "w") as f:
        f.write(payload)
    return path


def parse_prometheus(text: str) -> Dict[str, Dict[str, float]]:
    """Parse exposition text back into ``{metric_name: {label_block: value}}``.

    Histogram series come back under their expanded sample names
    (``name_bucket`` / ``name_sum`` / ``name_count``) — exactly what
    :meth:`MetricsRegistry.to_prometheus` emitted, so equality against a
    re-parse is the round-trip test.
    """
    out: Dict[str, Dict[str, float]] = {}
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        m = _SAMPLE_RE.match(line)
        if not m:
            raise ValueError(f"unparseable exposition line: {line!r}")
        name, labels, value = m.groups()
        # canonicalise the label block through the same formatter the
        # exporter uses (order preserved; parse validates syntax)
        block = ""
        if labels:
            pairs = _LABEL_RE.findall(labels)
            block = _metrics.label_str(tuple(k for k, _ in pairs),
                                       tuple(v for _, v in pairs))
        out.setdefault(name, {})[block] = float(value)
    return out
